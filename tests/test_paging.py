"""Block-paged KV cache: allocator invariants (property-tested like
SlotScheduler), prefix-cache semantics, and engine-level parity — the
paged serve loop must stay bit-identical to the solo batch-1 oracle."""

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.bandwidth import decode_kv_bytes
from repro.models import transformer as T
from repro.serve.engine import (DecodeEngine, Request,
                                acceptance_requests, solo_greedy)
from repro.serve.paging import (SINK_PAGE, AdmitPlan, PagedKV, PagePool,
                                PrefixCache)


# ---------------------------------------------------------------------------
# PagePool
# ---------------------------------------------------------------------------

def test_page_pool_basics():
    pool = PagePool(5)                       # pages 1..4 allocatable
    assert pool.n_free == 4 and pool.n_used == 0
    a, b = pool.alloc(), pool.alloc()
    assert (a, b) == (1, 2)                  # deterministic lowest-first
    pool.free(a)
    assert pool.alloc() == 1                 # freed pages return
    pool.ref(b)                              # second holder
    pool.free(b)
    assert pool.refcount(b) == 1 and pool.n_free == 2
    pool.free(b)
    assert pool.refcount(b) == 0 and pool.n_free == 3


def test_page_pool_guards():
    pool = PagePool(3)
    with pytest.raises(ValueError):
        pool.free(SINK_PAGE)                 # sink is never allocatable
    with pytest.raises(ValueError):
        pool.ref(1)                          # not allocated yet
    pool.alloc(), pool.alloc()
    with pytest.raises(RuntimeError):
        pool.alloc()                         # exhausted
    with pytest.raises(ValueError):
        PagePool(1)                          # needs room beyond the sink


def test_page_pool_properties():
    """Property (hypothesis): under any interleaving of alloc/ref/free,
    no page is handed out twice while live, freed pages return to the
    pool, and a page stays allocated while anyone references it."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(n_pages=st.integers(2, 12), n_ops=st.integers(0, 80),
           data=st.data())
    @settings(max_examples=60, deadline=None)
    def check(n_pages, n_ops, data):
        pool = PagePool(n_pages)
        live = {}                            # page -> expected refcount
        for _ in range(n_ops):
            acts = (["alloc"] if pool.n_free else []) \
                + (["ref", "free"] if live else [])
            if not acts:
                break
            act = data.draw(st.sampled_from(acts))
            if act == "alloc":
                page = pool.alloc()
                assert page not in live      # never double-allocated
                assert page != SINK_PAGE
                live[page] = 1
            elif act == "ref":
                page = data.draw(st.sampled_from(sorted(live)))
                pool.ref(page)
                live[page] += 1
            else:
                page = data.draw(st.sampled_from(sorted(live)))
                pool.free(page)
                live[page] -= 1
                if live[page] == 0:
                    del live[page]
            # a referenced page is never in the free pool; conservation
            assert all(pool.refcount(p) == c for p, c in live.items())
            assert pool.n_free + len(live) == n_pages - 1
        for page in list(live):              # drain: everything returns
            for _ in range(live.pop(page)):
                pool.free(page)
        assert pool.n_free == n_pages - 1

    check()


# ---------------------------------------------------------------------------
# PrefixCache + PagedKV planning
# ---------------------------------------------------------------------------

def test_prefix_cache_match_register_evict():
    pool = PagePool(8)
    cache = PrefixCache(page_size=4)
    toks = np.arange(10, dtype=np.int32)     # 2 full pages + tail of 2
    pages = [pool.alloc() for _ in range(3)]
    assert cache.register(toks, pages, pool) == 3
    assert all(pool.refcount(p) == 2 for p in pages)
    assert cache.register(toks, pages, pool) == 0   # idempotent
    got, covered = cache.match(toks)
    assert got == pages and covered == 10    # partial tail matches too
    got, covered = cache.match(toks[:7])     # different tail -> 1 page
    assert got == pages[:1] and covered == 4
    assert cache.match(np.arange(100, 104, dtype=np.int32))[1] == 0
    # slot release: cache holds its own reference, pages survive
    for p in pages:
        pool.free(p)
    assert all(pool.refcount(p) == 1 for p in pages)
    # eviction only reclaims unreferenced entries
    pool.ref(pages[0])                       # a slot still maps page 0
    freed = cache.evict(pool, 99)
    assert freed == 2 and pool.refcount(pages[0]) == 2
    assert cache.match(toks, peek=True)[1] == 4   # chain head survives


def test_prefix_cache_evicts_deepest_first():
    """One register/match walk stamps its whole chain with one lru
    clock, so eviction drops the DEEPEST link first — never a chain
    head whose orphaned descendants could no longer match yet would
    keep their pages refcounted."""
    pool = PagePool(8)
    cache = PrefixCache(page_size=4)
    toks = np.arange(12, dtype=np.int32)     # 3 full pages
    pages = [pool.alloc() for _ in range(3)]
    cache.register(toks, pages, pool)
    for p in pages:                          # slot releases its refs
        pool.free(p)
    assert cache.evict(pool, 1) == 1
    # the deepest entry went; head + middle still match
    got, covered = cache.match(toks, peek=True)
    assert got == pages[:2] and covered == 8
    assert pool.refcount(pages[2]) == 0      # page actually freed
    # a later touch of the head alone must not make deeper entries
    # look fresher than it
    cache.match(toks[:4])
    assert cache.evict(pool, 1) == 1
    assert cache.match(toks[:4], peek=True)[1] == 4   # head survives
    assert pool.refcount(pages[1]) == 0


def test_prefix_cache_register_restamps_existing_chain():
    """Extending a cached chain re-stamps the shallow links too, so a
    chain never ends up with a head older than its new deeper links
    (the orphaning order the per-key clock allowed)."""
    pool = PagePool(8)
    cache = PrefixCache(page_size=4)
    toks = np.arange(12, dtype=np.int32)
    pages = [pool.alloc() for _ in range(3)]
    cache.register(toks[:4], pages[:1], pool)
    # a different chain touched in between would otherwise out-age it
    other = np.arange(100, 104, dtype=np.int32)
    cache.register(other, [pool.alloc()], pool)
    cache.register(toks, pages, pool)        # extend the first chain
    for p in pages:
        pool.free(p)
    assert cache.evict(pool, 2) == 2
    # eviction took the first chain's two deepest links, not its head
    assert cache.match(toks, peek=True)[1] == 4
    assert cache.match(other, peek=True)[1] == 4


def test_paged_kv_admit_shares_full_pages():
    kv = PagedKV(n_slots=2, n_pages=9, page_size=4, max_pages=4)
    toks = np.arange(8, dtype=np.int32)
    plan = kv.admit(0, toks, need_tokens=12)
    assert plan == AdmitPlan(0, (), (), 3, False)
    kv.register_prefix(0, toks)
    # same prompt again: shared caps at plen-1=7, so page 0 is shared
    # in place and page 1 (position 7 recomputes into it) is COW'd;
    # fresh pages = COW dst + 1 growth page
    assert kv.pages_needed(toks, 12) == 2
    plan2 = kv.admit(1, toks, need_tokens=12)
    assert plan2.shared_tokens == 7 and plan2.prefix_hit
    assert plan2.cow_src == (kv.tables[0][1],)
    assert kv.tables[1][0] == kv.tables[0][0]      # shared in place
    assert kv.tables[1][1] != kv.tables[0][1]      # private copy
    kv.release(0)
    kv.release(1)
    # prefix cache still pins the published pages
    assert kv.pool.n_used == 2


def test_paged_kv_admit_cow_mid_page():
    """Identical prompt whose last token lands mid-page: everything up
    to plen-1 is shared, the tail page is duplicated copy-on-write."""
    kv = PagedKV(n_slots=2, n_pages=9, page_size=4, max_pages=4)
    toks = np.arange(6, dtype=np.int32)      # covers pages [0,4) + [4,6)
    kv.admit(0, toks, need_tokens=8)
    kv.register_prefix(0, toks)
    plan = kv.admit(1, toks, need_tokens=8)
    assert plan.shared_tokens == 5           # min(matched=6, plen-1)
    assert len(plan.cow_src) == 1
    assert plan.cow_src[0] == kv.tables[0][1]
    assert plan.cow_dst[0] == kv.tables[1][1]
    assert kv.pool.refcount(kv.tables[0][1]) == 2  # slot 0 + cache


def test_paged_kv_table_rows_and_reclaim():
    kv = PagedKV(n_slots=2, n_pages=4, page_size=4, max_pages=3)
    toks = np.arange(5, dtype=np.int32)
    kv.admit(0, toks, need_tokens=5)
    row = kv.table_row(0)
    assert row.shape == (3,) and row.dtype == np.int32
    assert list(row) == kv.tables[0] + [SINK_PAGE]
    masked = kv.masked_tables([])
    assert (masked == SINK_PAGE).all()       # mid-prefill slots stay sunk
    kv.register_prefix(0, toks)
    kv.release(0)
    other = np.arange(50, 58, dtype=np.int32)
    assert not kv.can_admit(other, 8)        # cache pins both pages
    assert kv.try_reclaim(other, 8)          # eviction frees them
    plan = kv.admit(1, other, 8)
    assert plan.n_pages == 2 and not plan.prefix_hit


# ---------------------------------------------------------------------------
# KV traffic billing
# ---------------------------------------------------------------------------

def test_decode_kv_bytes():
    per_tok = 2 * 2 * 64 * 2                 # k+v, hkv=2, d=64, bf16
    assert decode_kv_bytes([9], n_kv_heads=2, head_dim=64) \
        == 10 * per_tok
    # sliding window clamps the span
    assert decode_kv_bytes([99], n_kv_heads=2, head_dim=64,
                           window=32) == 32 * per_tok
    # paged billing rounds the span up to whole pages touched
    assert decode_kv_bytes([9], n_kv_heads=2, head_dim=64,
                           page_size=8) == 16 * per_tok
    # paged + window: the paged kernel has no ring buffer — windowed
    # layers page the FULL history and mask in-VMEM, so billing ignores
    # the window (pages 0..13, not just the window span)
    assert decode_kv_bytes([99], n_kv_heads=2, head_dim=64, window=32,
                           page_size=8) == 104 * per_tok
    # per-row sum and dtype width
    assert decode_kv_bytes([3, 7], n_kv_heads=2, head_dim=64,
                           dtype="float32") == (4 + 8) * 2 * per_tok


# ---------------------------------------------------------------------------
# Engine-level parity: paged serve == solo batch-1 greedy, bit for bit
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke_config("smollm-360m")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    solo = [solo_greedy(params, cfg, r.prompt, r.max_tokens, 64)
            for r in acceptance_requests(cfg.vocab)]
    return cfg, params, solo


def _run_engine(cfg, params, reqs, **kw):
    eng = DecodeEngine(params, cfg, batch=2, max_len=64, page_size=16,
                       **kw)
    res = eng.run(reqs)
    res.sort(key=lambda r: r.rid)
    return eng, res


def test_paged_acceptance_bit_identical(smoke):
    cfg, params, solo = smoke
    _, res = _run_engine(cfg, params, acceptance_requests(cfg.vocab),
                         prefix_cache=False)
    for r, want in zip(res, solo):
        np.testing.assert_array_equal(r.tokens, want)
        assert r.prefill_chunks == 1


def test_chunked_prefill_bit_identical(smoke):
    cfg, params, solo = smoke
    eng, res = _run_engine(cfg, params, acceptance_requests(cfg.vocab),
                           prefix_cache=False, prefill_chunk=8)
    for r, want in zip(res, solo):
        np.testing.assert_array_equal(r.tokens, want)
    # the 16- and 32-token prompts split into multiple chunks
    assert [r.prefill_chunks for r in res] == [1, 2, 1, 4]
    assert eng.metrics["prefill_chunks"] == 8


def test_prefix_sharing_divergent_continuations(smoke):
    """Two prompts sharing a 32-token prefix: the prefix prefills
    exactly once (counter-asserted) and both continuations stay
    bit-exact vs their solo runs."""
    cfg, params, _ = smoke
    rng = np.random.default_rng(5)
    pre = rng.integers(0, cfg.vocab, (32,)).astype(np.int32)
    prompts = [np.concatenate([pre, rng.integers(0, cfg.vocab, (8,))
                               .astype(np.int32)]) for _ in range(2)]
    solo = [solo_greedy(params, cfg, p, 8, 64) for p in prompts]
    eng, res = _run_engine(
        cfg, params,
        [Request(prompt=p, max_tokens=8) for p in prompts])
    for r, want in zip(res, solo):
        np.testing.assert_array_equal(r.tokens, want)
    m = eng.metrics
    assert m["prefix_hits"] == 1 and m["prefix_misses"] == 1
    assert m["shared_prompt_tokens"] == 32
    # request 0 prefills all 40 tokens, request 1 only its 8-token tail
    assert m["prefill_tokens"] == 40 + 8


def test_prefix_cow_identical_prompt(smoke):
    """An identical re-prompt shares everything but its final token —
    the mid-page tail is COW-duplicated and exactly 1 token recomputes."""
    cfg, params, _ = smoke
    rng = np.random.default_rng(6)
    p = rng.integers(0, cfg.vocab, (20,)).astype(np.int32)
    want = solo_greedy(params, cfg, p, 6, 64)
    eng = DecodeEngine(params, cfg, batch=1, max_len=64, page_size=8)
    r1 = eng.run([Request(prompt=p.copy(), max_tokens=6)])
    t1 = eng.metrics["prefill_tokens"]
    r2 = eng.run([Request(prompt=p.copy(), max_tokens=6)])
    np.testing.assert_array_equal(r1[0].tokens, want)
    np.testing.assert_array_equal(r2[0].tokens, want)
    assert t1 == 20
    assert eng.metrics["prefill_tokens"] - t1 == 1


def test_pool_exhaustion_queues_and_completes(smoke):
    """A pool smaller than the offered load head-of-line queues; every
    request still completes once earlier ones free their pages."""
    cfg, params, _ = smoke
    rng = np.random.default_rng(8)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, (16,))
                    .astype(np.int32), max_tokens=8) for _ in range(3)]
    eng, res = _run_engine(cfg, params, reqs, prefix_cache=False,
                           n_pages=1 + 3)    # 3 usable 16-token pages
    assert len(res) == 3
    assert all(r.n_tokens == 8 for r in res)
    assert eng.kv.pool.n_used == 0           # everything released


def test_submit_rejects_oversized_paged_request(smoke):
    cfg, params, _ = smoke
    eng = DecodeEngine(params, cfg, batch=1, max_len=64, page_size=16,
                       n_pages=1 + 2)        # 2 usable pages = 32 tokens
    with pytest.raises(ValueError, match="pages"):
        eng.submit(Request(prompt=np.zeros(40, np.int32), max_tokens=8))


def test_submit_rejects_frames_on_paged_engine(smoke):
    """A frames-carrying request must bounce at submit(), not blow up
    the serve loop mid-trace at admission."""
    cfg, params, _ = smoke
    eng = DecodeEngine(params, cfg, batch=1, max_len=64, page_size=16)
    with pytest.raises(ValueError, match="audio"):
        eng.submit(Request(prompt=np.zeros(4, np.int32), max_tokens=2,
                           frames=np.zeros((3, 8), np.float32)))


@pytest.mark.parametrize("arch", ["mamba2-370m", "recurrentgemma-9b"])
def test_paged_engine_rejects_recurrent_archs(arch):
    """ssm/rec stacks have per-slot recurrent state the page pool can't
    protect (stale state across chunked prefill, decode-burst writes
    into mid-prefill slots, no recurrence skip for shared prefixes) —
    the paged engine refuses them up front."""
    cfg = get_smoke_config(arch)
    with pytest.raises(ValueError, match="recurrent"):
        DecodeEngine({}, cfg, batch=1, max_len=64, page_size=16)
    with pytest.raises(AssertionError, match="recurrent"):
        jax.eval_shape(lambda: T.init_paged_cache(
            cfg, 1, n_pages=5, page_size=16, max_pages=4))
